"""Integration: end-to-end training (loss decreases, restart resumes) and
the serving engine (consistency with direct decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine
from repro.train.checkpoint import latest_step
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import FaultToleranceConfig, FaultTolerantRunner
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def tiny_model():
    # fp32 so greedy argmax is batch-size invariant (bf16 near-ties flip)
    cfg = reduced_config(get_config("stablelm-1.6b")).replace(
        name="tiny", n_layers=2, d_model=64, vocab_size=128,
        dtype="float32")
    return build_model(cfg, attn_impl="einsum")


def test_training_loss_decreases():
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt = adamw_init(params)
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=32, global_batch=8,
                                seed=1))
    losses = []
    for i in range(50):
        params, opt, m = step(params, opt, ds.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, \
        f"no learning: {losses[:3]} -> {losses[-3:]}"


def test_grad_accum_matches_full_batch():
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=32, global_batch=8,
                                seed=2))
    batch = ds.batch(0)
    s1 = jax.jit(make_train_step(model, opt_cfg, grad_accum=1))
    s4 = jax.jit(make_train_step(model, opt_cfg, grad_accum=4))
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p4, _, m4 = s4(params, adamw_init(params), batch)
    # same data => numerically close updates (fp32 accumulation order differs)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_fault_tolerant_restart(tmp_path):
    model = tiny_model()
    params0 = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    step = jax.jit(make_train_step(model, opt_cfg))
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=32, global_batch=8,
                                seed=3))
    ft_cfg = FaultToleranceConfig(ckpt_dir=str(tmp_path), ckpt_every=5)

    runner = FaultTolerantRunner(step, ft_cfg)
    out = runner.run(params0, adamw_init(params0), ds.batch, n_steps=12,
                     log_fn=lambda s: None)
    runner.manager.wait()
    assert latest_step(str(tmp_path)) == 12

    # "crash" and restart: resumes from the last commit, not from scratch
    runner2 = FaultTolerantRunner(step, ft_cfg)
    p, o, start = runner2.try_restore(params0, adamw_init(params0))
    assert start == 12
    out2 = runner2.run(p, o, ds.batch, n_steps=20, start_step=start,
                       log_fn=lambda s: None)
    assert out2["final_step"] == 20
    assert len(out2["losses"]) == 8


def test_straggler_watchdog():
    from repro.train.fault_tolerance import StepWatchdog
    wd = StepWatchdog(factor=2.0, window=10)
    for _ in range(8):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)
    assert wd.straggler_events == 1


# ---------------------------- serving ----------------------------

def test_serving_engine_end_to_end():
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(1))
    eng = ServingEngine(model, params, max_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(ServeRequest(rid=i,
                                prompt=rng.integers(1, 128, size=8),
                                max_new_tokens=5))
    done = eng.run()
    assert len(done) == 6
    for r in done:
        assert len(r.generated) == 5
        assert r.t_done >= r.t_first >= 0
    kinds = {l.kind for l in eng.logs}
    assert kinds == {"prefill", "decode"}


def test_serving_matches_sequential_decode():
    """Greedy tokens from the engine == tokens from hand-rolled decode."""
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(2))
    prompt = np.arange(1, 9)

    eng = ServingEngine(model, params, max_slots=2, max_len=64)
    eng.submit(ServeRequest(rid=0, prompt=prompt, max_new_tokens=6))
    done = eng.run()
    engine_tokens = done[0].generated

    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt)[None]}, max_len=64)
    ref = [int(jnp.argmax(logits[0]))]
    for _ in range(5):
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([[ref[-1]]])}, cache)
        ref.append(int(jnp.argmax(logits[0])))
    assert engine_tokens == ref
