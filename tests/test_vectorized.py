"""Vectorized-core equality pins.

The whole point of the array-native refactor is that nothing moves:
(a) the batched roofline kernel replayed over a logged trace is
bit-identical to what the event loop recorded stage by stage, (b) the
vectorized runner mode produces records bit-identical to the event
loop mode on every pinned benchmark grid (fig1/fig3/exp5 single-site,
exp6 fleet, exp7 shift), and (c) the stacked energy/carbon passes
equal their per-scenario counterparts exactly.
"""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs.paper_models import CODELLAMA_34B, LLAMA3_8B
from repro.core.energy import operational_energy, stacked_energy_reports
from repro.core.power import DEVICES, PowerModel
from repro.sim import (PAPER_DEFAULT, SchedulerConfig, SimConfig,
                       StageBatch, WorkloadConfig, run_simulation)
from repro.sim.execmodel import ExecutionModel, cached_execution_model
from repro.sweep import SWEEPS, GridSpec, SweepRunner
from repro.sweep.vectorized import group_by_trace


# ---------------------------------------------------------------------------
# runner-mode equality on the pinned benchmark grids
# ---------------------------------------------------------------------------

def _run_both_modes(scenarios):
    ev, _ = SweepRunner(cache=None, mode="event_loop").run(scenarios)
    ve, _ = SweepRunner(cache=None, mode="vectorized").run(scenarios)
    return ev, ve


def _assert_records_bit_identical(ev, ve):
    assert len(ev) == len(ve)
    for a, b in zip(ev, ve):
        assert a["scenario"] == b["scenario"]
        assert a["params"] == b["params"]
        assert a["key"] == b["key"]
        assert a["metrics"] == b["metrics"], a["scenario"]


@pytest.mark.parametrize("sweep", ["fig1", "fig3", "exp5"])
def test_modes_bit_identical_single_site(sweep):
    scenarios = SWEEPS[sweep].build(True, n_requests=16)
    ev, ve = _run_both_modes(scenarios)
    _assert_records_bit_identical(ev, ve)


@pytest.mark.parametrize("sweep", ["fleet", "shift"])
def test_modes_bit_identical_fleet(sweep):
    # exp6/exp7 grids: FleetConfig scenarios pass through the fleet
    # rollup in both modes — vectorized mode must not perturb them
    scenarios = SWEEPS[sweep].build(True, n_requests=10)
    ev, ve = _run_both_modes(scenarios)
    _assert_records_bit_identical(ev, ve)


def test_vectorized_groups_shared_traces():
    spec = GridSpec(base=PAPER_DEFAULT, tag="g",
                    axes={"workload.qps": [2.0, 5.0],
                          "pue": [1.0, 1.4],
                          "grid_ci": [50.0, 450.0]},
                    fixed={"workload.n_requests": 8,
                           "workload.min_len": 64,
                           "workload.max_len": 128})
    scenarios = spec.expand()
    groups = group_by_trace(scenarios)
    assert len(scenarios) == 8
    assert len(groups) == 2                    # one per qps point
    assert sorted(i for g in groups for i in g) == list(range(8))
    ev, ve = _run_both_modes(scenarios)
    _assert_records_bit_identical(ev, ve)
    # the shared-trace axes really move the metrics
    e = {r["params"]["pue"]: r["metrics"]["energy_wh"] for r in ve
         if r["params"]["qps"] == 2.0 and r["params"]["grid_ci"] == 50.0}
    assert e[1.4] == pytest.approx(e[1.0] * 1.4)
    c = {r["params"]["grid_ci"]: r["metrics"]["carbon_operational_g"]
         for r in ve
         if r["params"]["qps"] == 2.0 and r["params"]["pue"] == 1.0}
    assert c[450.0] == pytest.approx(c[50.0] * 9.0)


# ---------------------------------------------------------------------------
# trace replay: batched kernel == per-stage event-loop records
# ---------------------------------------------------------------------------

def _replay(res):
    em = cached_execution_model(res.cfg.model, res.cfg.device, res.cfg.tp,
                                res.cfg.pp, res.cfg.execmodel)
    return em.stage_cost_batch(StageBatch.from_trace(res.stages))


@pytest.mark.parametrize("chunk", [None, 256])
def test_stage_trace_replay_bit_identical(chunk):
    cfg = SimConfig(model=LLAMA3_8B,
                    workload=WorkloadConfig(n_requests=24, qps=4.0,
                                            min_len=64, max_len=512,
                                            seed=0),
                    scheduler=SchedulerConfig(batch_cap=8,
                                              chunk_prefill=chunk))
    res = run_simulation(cfg)
    cb = _replay(res)
    assert np.array_equal(cb.t_total, res.stages.dur_s)
    assert np.array_equal(cb.mfu, res.stages.mfu)
    assert np.array_equal(cb.flops_mlp, res.stages.flops_mlp)
    assert np.array_equal(cb.flops_attn, res.stages.flops_attn)


def test_fleet_site_trace_replays():
    from repro.fleet import run_fleet_simulation
    from repro.fleet.config import FleetConfig, SiteConfig

    cfg = FleetConfig(
        model=LLAMA3_8B,
        sites=(SiteConfig(name="a", ci_trace="hydro"),
               SiteConfig(name="b", ci_trace="coal")),
        workload=WorkloadConfig(n_requests=12, qps=4.0, min_len=64,
                                max_len=256, seed=0))
    res = run_fleet_simulation(cfg)
    for s in res.sites:
        em = cached_execution_model(cfg.model, s.site.device, s.site.tp,
                                    s.site.pp, cfg.execmodel)
        cb = em.stage_cost_batch(StageBatch.from_trace(s.stages))
        assert np.array_equal(cb.t_total, s.stages.dur_s)
        assert np.array_equal(cb.mfu, s.stages.mfu)


# ---------------------------------------------------------------------------
# scalar stage_cost == batched kernel rows (property test)
# ---------------------------------------------------------------------------

_COMPOSITION = st.tuples(
    st.lists(st.tuples(st.integers(1, 4096), st.integers(0, 4096)),
             min_size=0, max_size=5),                 # (chunk len, offset)
    st.lists(st.integers(1, 8192), min_size=0, max_size=8))  # decode ctxs


@given(st.lists(_COMPOSITION, min_size=1, max_size=12),
       st.sampled_from(["llama3-8b", "codellama-34b"]),
       st.sampled_from([(1, 1), (2, 1), (1, 2), (2, 2)]))
@settings(max_examples=25, deadline=None)
def test_batch_rows_match_scalar_path(comps, model_name, tp_pp):
    model = {"llama3-8b": LLAMA3_8B, "codellama-34b": CODELLAMA_34B}[model_name]
    tp, pp = tp_pp
    em = ExecutionModel(model, DEVICES["a100"], tp=tp, pp=pp)
    aggs, costs = [], []
    for (pre, ctxs) in comps:
        plens = [p for p, _ in pre]
        offs = [o for _, o in pre]
        aggs.append(em.aggregate(plens, ctxs, offs))
        costs.append(em.stage_cost(plens, ctxs, offs))
    cb = em.stage_cost_batch(StageBatch.concat(aggs))
    for i, c in enumerate(costs):
        assert cb.row(i) == c


def test_jax_backend_matches_numpy_closely():
    em = ExecutionModel(LLAMA3_8B, DEVICES["a100"])
    batch = StageBatch.concat([em.aggregate([512], [128, 4096]),
                               em.aggregate([], [64] * 32),
                               em.aggregate([128, 128], [], [0, 1024])])
    ref = em.stage_cost_batch(batch)
    jx = em.stage_cost_batch(batch, backend="jax")
    np.testing.assert_allclose(jx.t_total, ref.t_total, rtol=1e-4)
    np.testing.assert_allclose(jx.mfu, ref.mfu, rtol=1e-4)


# ---------------------------------------------------------------------------
# stacked energy pass == per-PUE operational_energy
# ---------------------------------------------------------------------------

def test_stacked_energy_reports_bit_identical():
    rng = np.random.default_rng(5)
    mfu = rng.uniform(0.0, 0.6, 200)
    dt = rng.uniform(1e-3, 2.0, 200)
    pm = PowerModel("a100")
    pues = [1.0, 1.12, 1.5, 2.0]
    stacked = stacked_energy_reports(mfu, dt, pm, n_devices=4, pues=pues)
    for pue, rep in zip(pues, stacked):
        solo = operational_energy(mfu, dt, pm, n_devices=4, pue=pue)
        assert rep == solo


# ---------------------------------------------------------------------------
# chunked-prefill accounting (cross-chunk KV reads + score context)
# ---------------------------------------------------------------------------

def test_chunk_offset_adds_kv_read_and_score_context():
    em = ExecutionModel(LLAMA3_8B, DEVICES["a100"])
    fresh = em.aggregate([256], [], [0])
    cont = em.aggregate([256], [], [2048])
    kvpt = LLAMA3_8B.kv_bytes_per_token()
    # the continuation re-reads exactly the prior context's KV
    assert cont.kv_rw_bytes[0] - fresh.kv_rw_bytes[0] == \
        pytest.approx(2048 * kvpt)
    # and its score FLOPs see the offset context
    assert cont.score_flops[0] > fresh.score_flops[0]
    # continuation chunks therefore cost more wall-clock than a fresh
    # chunk of the same size (the under-counting the fix removes)
    t_fresh = em.stage_cost([256], [], [0]).t_total
    t_cont = em.stage_cost([256], [], [2048]).t_total
    assert t_cont > t_fresh


def test_chunked_prefill_conserves_score_flops():
    """Summed over all chunks, score FLOPs must match the whole-prompt
    prefill (each token's average context is preserved by offsetting),
    where the old accounting under-counted by ~2x at 4 chunks."""
    em = ExecutionModel(LLAMA3_8B, DEVICES["a100"])
    L, C = 4096, 512
    whole = em.aggregate([L], []).score_flops[0]
    chunked = sum(
        em.aggregate([C], [], [off]).score_flops[0]
        for off in range(0, L, C))
    assert chunked == pytest.approx(whole, rel=0.01)


def test_chunked_prefill_charges_more_memory_traffic():
    """End to end: a chunked run must log at least the unchunked run's
    KV traffic for the same workload (cross-chunk reads added)."""
    def kv_total(chunk):
        wl = WorkloadConfig(n_requests=4, qps=1.0, min_len=1024,
                            max_len=1024, length_dist="fixed", seed=0)
        res = run_simulation(SimConfig(
            model=LLAMA3_8B, workload=wl,
            scheduler=SchedulerConfig(batch_cap=8, chunk_prefill=chunk)))
        return float(np.sum(res.stages.kv_rw_bytes))

    assert kv_total(256) > kv_total(None)


# ---------------------------------------------------------------------------
# per-model invariants cached at construction
# ---------------------------------------------------------------------------

def test_execution_model_caches_invariants():
    em = ExecutionModel(LLAMA3_8B, DEVICES["a100"])
    assert em.active_params == LLAMA3_8B.active_param_count()
    assert em.kv_bytes_per_token == LLAMA3_8B.kv_bytes_per_token(2)
    assert em.fpt_mlp == LLAMA3_8B.flops_per_token_mlp_total()
    # linearized score model reproduces the config method exactly
    for ctx in (1, 17, 1024, 100_000):
        assert em._score_per_token(ctx) == \
            LLAMA3_8B.flops_attn_score_per_token(ctx)
    # the process-level constructor cache returns shared instances
    a = cached_execution_model(LLAMA3_8B, "a100", 1, 1, em.cfg)
    b = cached_execution_model(LLAMA3_8B, "a100", 1, 1, em.cfg)
    assert a is b
    assert cached_execution_model(LLAMA3_8B, "a100", 2, 1, em.cfg) is not a
