"""repro.workloads: envelopes, burst overlays, arrival streams.

Property tests (hypothesis, skipped gracefully when unavailable) pin
the stream generator's contracts: arrival-count conservation under
epoch splitting, rate-envelope linearity, per-seed determinism across
process boundaries, and bit-compatibility of the ``none`` envelope
with the legacy constant-rate draw.
"""
import subprocess
import sys

import numpy as np

from repro.sim.hybrid import epoch_bounds
from repro.sim.requests import WorkloadConfig, zipf_lengths
from repro.workloads import (cumulative_rate, envelope_shape,
                             generate_stream, rate_on_grid,
                             burst_overlay)

from _hypothesis_support import given, settings, st


def wl(n=400, qps=2.0, seed=0, **kw):
    return WorkloadConfig(n_requests=n, qps=qps, seed=seed,
                          min_len=64, max_len=256, **kw)


# ------------------------------------------------ count conservation ----

@given(n=st.integers(min_value=1, max_value=600),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       epoch_s=st.floats(min_value=10.0, max_value=600.0),
       envelope=st.sampled_from(["none", "sinusoidal", "diurnal"]),
       burst_gain=st.floats(min_value=1.0, max_value=4.0))
@settings(max_examples=25, deadline=None)
def test_epoch_splitting_conserves_arrival_count(n, seed, epoch_s,
                                                 envelope, burst_gain):
    """Splitting a stream into epochs never drops or duplicates a
    request: per-epoch counts over bounds that cover the stream sum
    to n, and the row ranges tile [0, n) without overlap."""
    stream = generate_stream(wl(
        n=n, seed=seed, envelope=envelope, envelope_period_h=1.0,
        burst_gain=burst_gain, burst_mean_s=60.0,
        burst_idle_mean_s=240.0)).sorted_by_ready()
    bounds = epoch_bounds(float(stream.ready_s[-1]), epoch_s)
    counts = stream.counts(bounds)
    assert counts.sum() == n
    lo = 0
    for e in range(len(bounds) - 1):
        i0, i1 = stream.window(float(bounds[e]), float(bounds[e + 1]))
        assert i0 == lo and i1 - i0 == counts[e]
        lo = i1
    assert lo == n


# ------------------------------------------------ envelope linearity ----

@given(qps=st.floats(min_value=0.1, max_value=50.0),
       k=st.floats(min_value=0.1, max_value=20.0),
       amplitude=st.floats(min_value=0.0, max_value=0.9),
       envelope=st.sampled_from(["none", "sinusoidal", "diurnal"]))
@settings(max_examples=25, deadline=None)
def test_rate_envelope_scales_linearly_in_qps(qps, k, amplitude,
                                              envelope):
    """lambda(t) = qps * envelope(t) * burst(t) is linear in qps: the
    grid rate and its cumulative integral scale by exactly k."""
    burst = burst_overlay(3, 3600.0, 2.0, 120.0, 600.0)
    t1, lam1 = rate_on_grid(qps, envelope, amplitude, 1.0, 0.0,
                            burst, 3600.0)
    t2, lam2 = rate_on_grid(k * qps, envelope, amplitude, 1.0, 0.0,
                            burst, 3600.0)
    np.testing.assert_allclose(lam2, k * lam1, rtol=1e-12)
    np.testing.assert_allclose(cumulative_rate(t2, lam2),
                               k * cumulative_rate(t1, lam1), rtol=1e-12)


def test_envelope_mean_stays_near_one():
    """The diurnal modulation keeps qps the day-average rate: the
    envelope's mean over a full period stays ~1."""
    t = np.linspace(0.0, 24 * 3600.0, 24 * 360, endpoint=False)
    for name in ("sinusoidal", "diurnal"):
        shape = envelope_shape(name, t, 0.35, 24.0, 0.0)
        assert abs(shape.mean() - 1.0) < 0.12, name
        assert shape.min() >= 0.05


# ------------------------------------------------ per-seed determinism ----

_SUBPROCESS_PROBE = """
import json, sys
import numpy as np
from repro.sim.requests import WorkloadConfig
from repro.workloads import generate_stream
s = generate_stream(WorkloadConfig(
    n_requests=300, qps=3.0, seed=7, min_len=64, max_len=256,
    envelope="diurnal", envelope_amplitude=0.4, burst_gain=2.5,
    burst_mean_s=90.0, burst_idle_mean_s=400.0, deferrable_frac=0.3))
print(json.dumps({
    "arrival": s.arrival_s.tobytes().hex(),
    "prefill": s.prefill_tokens.tobytes().hex(),
    "decode": s.decode_tokens.tobytes().hex(),
    "deferrable": s.deferrable.tobytes().hex(),
}))
"""


def test_stream_deterministic_across_process_boundaries():
    """The same (seed, config) reproduces the stream bit-for-bit in a
    fresh interpreter — sweep cache keys and CI pins rely on it."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROBE],
        capture_output=True, text=True, check=True)
    import json
    remote = json.loads(out.stdout)
    s = generate_stream(WorkloadConfig(
        n_requests=300, qps=3.0, seed=7, min_len=64, max_len=256,
        envelope="diurnal", envelope_amplitude=0.4, burst_gain=2.5,
        burst_mean_s=90.0, burst_idle_mean_s=400.0, deferrable_frac=0.3))
    assert s.arrival_s.tobytes().hex() == remote["arrival"]
    assert s.prefill_tokens.tobytes().hex() == remote["prefill"]
    assert s.decode_tokens.tobytes().hex() == remote["decode"]
    assert s.deferrable.tobytes().hex() == remote["deferrable"]


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_lengths_invariant_across_envelopes(seed):
    """Enabling an envelope or burst overlay only moves arrival
    *times*: the length/class draws consume the generator identically,
    so per-seed token splits and class tags never change."""
    base = generate_stream(wl(seed=seed, deferrable_frac=0.25))
    for envelope, gain in (("sinusoidal", 1.0), ("diurnal", 3.0)):
        mod = generate_stream(wl(
            seed=seed, deferrable_frac=0.25, envelope=envelope,
            envelope_period_h=1.0, burst_gain=gain,
            burst_mean_s=60.0, burst_idle_mean_s=300.0))
        np.testing.assert_array_equal(mod.prefill_tokens,
                                      base.prefill_tokens)
        np.testing.assert_array_equal(mod.decode_tokens,
                                      base.decode_tokens)
        np.testing.assert_array_equal(mod.deferrable, base.deferrable)


# ------------------------------------------------ legacy bit-compat ----

def test_none_envelope_keeps_legacy_stream_bitwise():
    """envelope="none" + burst_gain<=1 must reproduce the legacy
    constant-rate draw bit-for-bit (sweep caches and golden records
    from before repro.workloads depend on it)."""
    cfg = wl(n=500, qps=6.45, seed=3, deferrable_frac=0.2)
    stream = generate_stream(cfg)
    rng = np.random.default_rng(cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / cfg.qps, cfg.n_requests))
    lengths = zipf_lengths(rng, cfg.n_requests, cfg.zipf_theta,
                           cfg.min_len, cfg.max_len)
    pf = cfg.pd_ratio / (cfg.pd_ratio + 1.0)
    prefills = np.maximum(1, np.round(lengths * pf)).astype(int)
    deferrable = rng.random(cfg.n_requests) < cfg.deferrable_frac
    np.testing.assert_array_equal(stream.arrival_s, arrivals)
    np.testing.assert_array_equal(stream.prefill_tokens, prefills)
    np.testing.assert_array_equal(
        stream.decode_tokens, np.maximum(1, lengths - prefills))
    np.testing.assert_array_equal(stream.deferrable, deferrable)


def test_to_requests_matches_legacy_generate():
    """Materialized rows equal the legacy Request-list generator."""
    from repro.sim.requests import generate
    cfg = wl(n=64, seed=5, deferrable_frac=0.3)
    reqs = generate(cfg)
    rows = generate_stream(cfg).to_requests()
    assert len(reqs) == len(rows) == 64
    for a, b in zip(reqs, rows):
        assert (a.rid, a.arrival_s, a.prefill_tokens, a.decode_tokens,
                a.klass) == (b.rid, b.arrival_s, b.prefill_tokens,
                             b.decode_tokens, b.klass)
